//! Corpus-driven tests for the contract linter.
//!
//! Two layers:
//!
//! 1. `lint_source` assertions pin the *exact* `(rule, line)` findings and
//!    suppressions for every fixture in `tests/lint_corpus/` -- the corpus
//!    is the executable spec for the lexer's tricky cases (`unsafe` in a
//!    string literal, SAFETY separated by an attribute, `cfg(test)`
//!    nesting, pragma hygiene).
//! 2. Binary tests spawn the real `contract_lint` executable against
//!    throwaway trees assembled from the same fixtures and pin the exit
//!    codes: 0 on a clean tree, 1 on every bad fixture, 2 on usage errors.

use std::fs;
use std::path::{Path, PathBuf};
use std::process::Command;

use contract_lint::{lint_source, Report};

fn fixture(name: &str) -> String {
    let p = Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("tests/lint_corpus")
        .join(name);
    fs::read_to_string(&p).unwrap_or_else(|e| panic!("reading {}: {e}", p.display()))
}

fn hits(r: &Report) -> Vec<(String, usize)> {
    r.findings.iter().map(|f| (f.rule.clone(), f.line)).collect()
}

fn quiet(r: &Report) -> Vec<(String, usize)> {
    r.suppressed.iter().map(|s| (s.rule.clone(), s.line)).collect()
}

fn pairs(v: &[(&str, usize)]) -> Vec<(String, usize)> {
    v.iter().map(|(r, l)| (r.to_string(), *l)).collect()
}

// ------------------------------------------------- exact-finding layer

#[test]
fn bad_unsafe_no_safety_flags_both_sites() {
    let r = lint_source("x.rs", &fixture("bad_unsafe_no_safety.rs"), false, false);
    assert_eq!(hits(&r), pairs(&[("safety", 3), ("safety", 9)]));
    assert!(quiet(&r).is_empty());
}

#[test]
fn good_unsafe_safety_is_clean_under_all_rules() {
    // Attribute between SAFETY comment and item, `# Safety` doc section,
    // and `unsafe` inside a string literal: none may fire, even with the
    // serving and fma scopes both on.
    let r = lint_source("x.rs", &fixture("good_unsafe_safety.rs"), true, true);
    assert!(hits(&r).is_empty(), "unexpected findings: {:?}", hits(&r));
    assert!(quiet(&r).is_empty());
}

#[test]
fn bad_fma_flags_intrinsics_and_mul_add() {
    let r = lint_source("x.rs", &fixture("bad_fma.rs"), false, true);
    assert_eq!(hits(&r), pairs(&[("fma", 4), ("fma", 8), ("fma", 12)]));
}

#[test]
fn fma_outside_the_reach_scope_is_ignored() {
    let r = lint_source("x.rs", &fixture("bad_fma.rs"), false, false);
    assert!(hits(&r).is_empty());
}

#[test]
fn good_fma_strings_masked_tokens_do_not_count() {
    let r = lint_source("x.rs", &fixture("good_fma_strings.rs"), true, true);
    assert!(hits(&r).is_empty(), "unexpected findings: {:?}", hits(&r));
}

#[test]
fn bad_panic_serving_flags_every_token_outside_tests() {
    let r = lint_source("x.rs", &fixture("bad_panic_serving.rs"), true, false);
    assert_eq!(
        hits(&r),
        pairs(&[("panic", 4), ("panic", 5), ("panic", 6), ("panic", 8)])
    );
}

#[test]
fn panic_rule_only_applies_to_serving_files() {
    let r = lint_source("x.rs", &fixture("bad_panic_serving.rs"), false, false);
    assert!(hits(&r).is_empty());
}

#[test]
fn good_panic_tests_nested_test_modules_are_exempt() {
    let r = lint_source("x.rs", &fixture("good_panic_tests.rs"), true, false);
    assert!(hits(&r).is_empty(), "unexpected findings: {:?}", hits(&r));
}

#[test]
fn bad_index_arith_flags_computed_offsets() {
    let r = lint_source("x.rs", &fixture("bad_index_arith.rs"), true, false);
    assert_eq!(hits(&r), pairs(&[("index", 4)]));
}

#[test]
fn good_index_plain_macros_attrs_are_clean() {
    let r = lint_source("x.rs", &fixture("good_index.rs"), true, false);
    assert!(hits(&r).is_empty(), "unexpected findings: {:?}", hits(&r));
}

#[test]
fn bad_send_discard_flags_the_let_underscore() {
    let r = lint_source("x.rs", &fixture("bad_send_discard.rs"), true, false);
    assert_eq!(hits(&r), pairs(&[("send-discard", 6)]));
}

#[test]
fn good_send_pragma_suppresses_and_audits() {
    let r = lint_source("x.rs", &fixture("good_send_pragma.rs"), true, false);
    assert!(hits(&r).is_empty(), "unexpected findings: {:?}", hits(&r));
    assert_eq!(quiet(&r), pairs(&[("send-discard", 8)]));
    assert_eq!(r.suppressed[0].reason, "best-effort shutdown notification");
}

#[test]
fn bad_pragma_hygiene_findings_cannot_be_suppressed() {
    let r = lint_source("x.rs", &fixture("bad_pragma.rs"), true, false);
    // A reason-less pragma still suppresses (one finding, not two); an
    // unknown rule name suppresses nothing, so the index below it fires.
    assert_eq!(
        hits(&r),
        pairs(&[("pragma", 4), ("pragma", 9), ("index", 10)])
    );
    assert_eq!(quiet(&r), pairs(&[("panic", 5)]));
}

// --------------------------------------------------- binary exit codes

struct TempTree {
    root: PathBuf,
}

impl TempTree {
    fn new(tag: &str) -> Self {
        let root = std::env::temp_dir().join(format!(
            "contract_lint_corpus_{}_{tag}",
            std::process::id()
        ));
        let _ = fs::remove_dir_all(&root);
        fs::create_dir_all(&root).unwrap();
        TempTree { root }
    }

    fn put(&self, rel: &str, contents: &str) -> &Self {
        let p = self.root.join(rel);
        fs::create_dir_all(p.parent().unwrap()).unwrap();
        fs::write(&p, contents).unwrap();
        self
    }
}

impl Drop for TempTree {
    fn drop(&mut self) {
        let _ = fs::remove_dir_all(&self.root);
    }
}

fn run_lint(args: &[&std::ffi::OsStr]) -> (i32, String) {
    let out = Command::new(env!("CARGO_BIN_EXE_contract_lint"))
        .args(args)
        .output()
        .expect("spawning contract_lint");
    let code = out.status.code().unwrap_or(-1);
    (code, String::from_utf8_lossy(&out.stdout).into_owned())
}

const WIRE_V1: &str = "pub const WIRE_VERSION: u16 = 1;\n";
const DOC_V1: &str = "# wire\n\ncontract-lint: wire-version = 1\n";

#[test]
fn clean_tree_exits_zero_and_reports_suppressions() {
    let t = TempTree::new("clean");
    t.put("util.rs", &fixture("good_unsafe_safety.rs"))
        .put("rfc/kernel.rs", &fixture("good_fma_strings.rs"))
        .put("rfc/wire.rs", WIRE_V1)
        .put("sim/rfc.rs", WIRE_V1)
        .put("coordinator/a.rs", &fixture("good_panic_tests.rs"))
        .put("coordinator/b.rs", &fixture("good_index.rs"))
        .put("coordinator/c.rs", &fixture("good_send_pragma.rs"))
        .put("wire-format.md", DOC_V1);
    let doc = t.root.join("wire-format.md");
    let (code, out) = run_lint(&[
        "--wire-doc".as_ref(),
        doc.as_os_str(),
        t.root.as_os_str(),
    ]);
    assert_eq!(code, 0, "expected exit 0, output:\n{out}");
    assert!(out.contains("1 suppression(s)"), "audit missing:\n{out}");
}

#[test]
fn every_bad_fixture_exits_one() {
    // Each bad fixture is planted where its rule applies: fma findings
    // need the kernel reach set, serving rules need coordinator/*.
    let cases = [
        ("bad_unsafe_no_safety.rs", "util.rs"),
        ("bad_fma.rs", "rfc/kernel.rs"),
        ("bad_panic_serving.rs", "coordinator/x.rs"),
        ("bad_index_arith.rs", "coordinator/x.rs"),
        ("bad_send_discard.rs", "coordinator/x.rs"),
        ("bad_pragma.rs", "coordinator/x.rs"),
    ];
    for (name, dest) in cases {
        let tag = name.trim_end_matches(".rs");
        let t = TempTree::new(tag);
        t.put(dest, &fixture(name));
        let (code, out) = run_lint(&[t.root.as_os_str()]);
        assert_eq!(code, 1, "{name} at {dest}: expected exit 1, output:\n{out}");
    }
}

#[test]
fn wire_version_skew_exits_one() {
    // sim mirror lags the wire implementation
    let t = TempTree::new("wire_skew");
    t.put("rfc/wire.rs", "pub const WIRE_VERSION: u16 = 2;\n")
        .put("sim/rfc.rs", WIRE_V1)
        .put("wire-format.md", "# wire\n\ncontract-lint: wire-version = 2\n");
    let doc = t.root.join("wire-format.md");
    let (code, out) = run_lint(&[
        "--wire-doc".as_ref(),
        doc.as_os_str(),
        t.root.as_os_str(),
    ]);
    assert_eq!(code, 1, "expected exit 1, output:\n{out}");
    assert!(out.contains("[wire-version]"), "wrong rule fired:\n{out}");

    // ADR carries no machine-readable marker at all
    let t2 = TempTree::new("wire_nodoc");
    t2.put("rfc/wire.rs", WIRE_V1)
        .put("sim/rfc.rs", WIRE_V1)
        .put("wire-format.md", "# wire, no marker\n");
    let doc2 = t2.root.join("wire-format.md");
    let (code2, out2) = run_lint(&[
        "--wire-doc".as_ref(),
        doc2.as_os_str(),
        t2.root.as_os_str(),
    ]);
    assert_eq!(code2, 1, "expected exit 1, output:\n{out2}");
    assert!(out2.contains("[wire-version]"), "wrong rule fired:\n{out2}");
}

#[test]
fn usage_errors_exit_two() {
    let (code, _) = run_lint(&["--frobnicate".as_ref()]);
    assert_eq!(code, 2);
    let (code, _) = run_lint(&["/nonexistent/contract_lint/root".as_ref()]);
    assert_eq!(code, 2);
}
