//! In-tree static analysis for the repo's correctness contracts.
//!
//! A comment-and-string-aware lexer over `rust/src/**` that enforces the
//! rules prose alone kept failing to (see `docs/static-analysis.md` for
//! the catalog, motivating incidents, and the allow-pragma policy):
//!
//! * `safety` -- every `unsafe` token is justified by an immediately
//!   preceding `// SAFETY:` comment (or a `/// # Safety` doc section),
//!   attributes and continuation comment lines allowed in between;
//! * `fma` -- FMA intrinsics (`*fmadd*`, `vfma*`, `mul_add`) are
//!   forbidden in `rfc/kernel.rs` and every module it reaches via `use`,
//!   protecting the lane-ascending separate-multiply-add accumulation
//!   that keeps SIMD bit-identical to the scalar reference;
//! * `panic` -- `unwrap()` / `.expect(` / `panic!` / `debug_assert!` /
//!   `unreachable!` / `todo!` / `unimplemented!` are forbidden on the
//!   serving path (`coordinator/*`, `rfc/wire.rs`) outside test regions;
//! * `index` -- bracket indexing whose index expression contains
//!   arithmetic (`+ - * / %`) is forbidden on the serving path: the
//!   computed-offset slicing class of panics (a type-blind heuristic;
//!   plain `x[i]` is left to `clippy::indexing_slicing` if ever wanted);
//! * `send-discard` -- `let _ = ...send(..)` on the serving path is
//!   forbidden: a discarded send result hides an abandoned caller;
//! * `wire-version` -- the `WIRE_VERSION` constants in `rfc/wire.rs` and
//!   `sim/rfc.rs` and the `contract-lint: wire-version = N` marker in
//!   the wire-format ADR must all agree.
//!
//! Violations are suppressible only via an inline
//! `// lint: allow(<rule>): <reason>` pragma on the offending line or
//! immediately above it (attribute lines skipped); pragmas are counted
//! and reported so exceptions stay auditable. A pragma naming an unknown
//! rule or missing its reason is itself a finding (rule `pragma`).
//!
//! The lexer masks comments, string/char literals, and raw strings to
//! spaces before any rule runs, so `"unsafe"` in a string or `fmadd` in
//! a comment can never trip a rule; test regions (`#[cfg(test)]` /
//! `mod tests`) are tracked by brace depth.

use std::collections::{BTreeMap, BTreeSet};
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

/// The public rule names accepted by `lint: allow(...)` pragmas.
pub const RULES: &[&str] = &[
    "safety",
    "fma",
    "panic",
    "index",
    "wire-version",
    "send-discard",
];

/// One violation. `line` is 1-based.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Finding {
    pub file: String,
    pub line: usize,
    pub rule: String,
    pub message: String,
}

/// One pragma-suppressed would-be violation, kept for the audit report.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Suppression {
    pub file: String,
    pub line: usize,
    pub rule: String,
    pub reason: String,
}

/// Everything one lint run produced.
#[derive(Debug, Default)]
pub struct Report {
    pub findings: Vec<Finding>,
    pub suppressed: Vec<Suppression>,
}

impl Report {
    fn sort(&mut self) {
        self.findings.sort_by(|a, b| {
            (&a.file, a.line, &a.rule, &a.message)
                .cmp(&(&b.file, b.line, &b.rule, &b.message))
        });
        self.suppressed.sort_by(|a, b| {
            (&a.file, a.line, &a.rule).cmp(&(&b.file, b.line, &b.rule))
        });
    }
}

// ------------------------------------------------------------- lexer

fn is_ident_b(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

fn push_masked(out: &mut String, line: &mut usize, c: char, as_code: bool) {
    if c == '\n' {
        out.push('\n');
        *line += 1;
    } else if as_code {
        out.push(c);
    } else {
        out.push(' ');
    }
}

/// Mask comments, strings, chars, and raw strings to spaces (newlines
/// kept, so line numbers survive); collect per-line comment text.
fn mask(src: &str) -> (String, BTreeMap<usize, String>) {
    let chars: Vec<char> = src.chars().collect();
    let n = chars.len();
    let mut out = String::with_capacity(src.len());
    let mut comments: BTreeMap<usize, String> = BTreeMap::new();
    let mut line = 0usize;
    let mut i = 0usize;
    while i < n {
        let c = chars[i];
        let nxt = if i + 1 < n { chars[i + 1] } else { '\0' };
        let prev_ident = i > 0 && (chars[i - 1].is_alphanumeric() || chars[i - 1] == '_');
        // line comment
        if c == '/' && nxt == '/' {
            while i < n && chars[i] != '\n' {
                comments.entry(line).or_default().push(chars[i]);
                push_masked(&mut out, &mut line, chars[i], false);
                i += 1;
            }
            continue;
        }
        // block comment (nestable)
        if c == '/' && nxt == '*' {
            let mut depth = 0usize;
            while i < n {
                if chars[i] == '/' && i + 1 < n && chars[i + 1] == '*' {
                    depth += 1;
                    for _ in 0..2 {
                        comments.entry(line).or_default().push(chars[i]);
                        push_masked(&mut out, &mut line, chars[i], false);
                        i += 1;
                    }
                } else if chars[i] == '*' && i + 1 < n && chars[i + 1] == '/' {
                    depth -= 1;
                    for _ in 0..2 {
                        comments.entry(line).or_default().push(chars[i]);
                        push_masked(&mut out, &mut line, chars[i], false);
                        i += 1;
                    }
                    if depth == 0 {
                        break;
                    }
                } else {
                    comments.entry(line).or_default().push(chars[i]);
                    push_masked(&mut out, &mut line, chars[i], false);
                    i += 1;
                }
            }
            continue;
        }
        // raw string: r"..." r#"..."# br"..." (not when r is part of an
        // identifier)
        if !prev_ident && (c == 'r' || (c == 'b' && nxt == 'r')) {
            let mut k = i;
            if chars[k] == 'b' {
                k += 1;
            }
            // chars[k] == 'r' here
            let mut h = k + 1;
            while h < n && chars[h] == '#' {
                h += 1;
            }
            if h < n && chars[h] == '"' {
                let hashes = h - (k + 1);
                // scan for closing quote + same number of hashes
                let mut j = h + 1;
                let end = loop {
                    if j >= n {
                        break n;
                    }
                    if chars[j] == '"' {
                        let mut m = 0usize;
                        while m < hashes && j + 1 + m < n && chars[j + 1 + m] == '#' {
                            m += 1;
                        }
                        if m == hashes {
                            break j + 1 + hashes;
                        }
                    }
                    j += 1;
                };
                for k2 in i..end {
                    push_masked(&mut out, &mut line, chars[k2], false);
                }
                i = end;
                continue;
            }
            // `r` / `br` not followed by a raw string: fall through
        }
        // normal (or byte) string
        if c == '"' || (c == 'b' && nxt == '"' && !prev_ident) {
            let mut j = i + if c == 'b' { 2 } else { 1 };
            while j < n {
                if chars[j] == '\\' {
                    j += 2;
                } else if chars[j] == '"' {
                    j += 1;
                    break;
                } else {
                    j += 1;
                }
            }
            let end = j.min(n);
            for k2 in i..end {
                push_masked(&mut out, &mut line, chars[k2], false);
            }
            i = end;
            continue;
        }
        // char literal vs lifetime
        if c == '\'' {
            if nxt == '\\' {
                let mut j = i + 2;
                while j < n && chars[j] != '\'' {
                    j += 1;
                }
                let end = (j + 1).min(n);
                for k2 in i..end {
                    push_masked(&mut out, &mut line, chars[k2], false);
                }
                i = end;
                continue;
            }
            if i + 2 < n && chars[i + 2] == '\'' && nxt != '\'' {
                for k2 in i..i + 3 {
                    push_masked(&mut out, &mut line, chars[k2], false);
                }
                i += 3;
                continue;
            }
            // lifetime tick: stays as code
            push_masked(&mut out, &mut line, c, true);
            i += 1;
            continue;
        }
        push_masked(&mut out, &mut line, c, true);
        i += 1;
    }
    (out, comments)
}

// --------------------------------------------------- scanning helpers

fn skip_ws(b: &[u8], mut i: usize) -> usize {
    while i < b.len() && (b[i] as char).is_whitespace() {
        i += 1;
    }
    i
}

/// Byte positions where `word` occurs with non-ident chars on both sides.
fn word_positions(s: &str, word: &str) -> Vec<usize> {
    let sb = s.as_bytes();
    let mut out = Vec::new();
    let mut from = 0usize;
    while from <= s.len() {
        let rel = match s[from..].find(word) {
            Some(p) => p,
            None => break,
        };
        let at = from + rel;
        let before_ok = at == 0 || !is_ident_b(sb[at - 1]);
        let end = at + word.len();
        let after_ok = end >= sb.len() || !is_ident_b(sb[end]);
        if before_ok && after_ok {
            out.push(at);
        }
        from = end;
    }
    out
}

/// Whitespace-stripped copy of a line (for attribute matching).
fn compact(line: &str) -> String {
    line.chars().filter(|c| !c.is_whitespace()).collect()
}

fn has_cfg_test(line: &str) -> bool {
    compact(line).contains("#[cfg(test)]")
}

fn has_mod_tests(line: &str) -> bool {
    let b = line.as_bytes();
    for at in word_positions(line, "mod") {
        let j = skip_ws(b, at + 3);
        if j == at + 3 {
            continue; // need whitespace between `mod` and the name
        }
        if line[j..].starts_with("tests") {
            let end = j + 5;
            if end >= b.len() || !is_ident_b(b[end]) {
                return true;
            }
        }
    }
    false
}

/// `(hit position, token name)` for every panic-family token on a line.
fn panic_hits(line: &str) -> Vec<(usize, &'static str)> {
    let b = line.as_bytes();
    let mut hits = Vec::new();
    for at in word_positions(line, "unwrap") {
        if at == 0 || b[at - 1] != b'.' {
            continue;
        }
        let j = skip_ws(b, at + 6);
        if j < b.len() && b[j] == b'(' {
            let k = skip_ws(b, j + 1);
            if k < b.len() && b[k] == b')' {
                hits.push((at, "unwrap()"));
            }
        }
    }
    for at in word_positions(line, "expect") {
        if at == 0 || b[at - 1] != b'.' {
            continue;
        }
        let j = skip_ws(b, at + 6);
        if j < b.len() && b[j] == b'(' {
            hits.push((at, ".expect("));
        }
    }
    let macros: &[(&str, &'static str)] = &[
        ("panic", "panic!"),
        ("debug_assert", "debug_assert!"),
        ("debug_assert_eq", "debug_assert!"),
        ("debug_assert_ne", "debug_assert!"),
        ("unreachable", "unreachable!"),
        ("todo", "todo!"),
        ("unimplemented", "unimplemented!"),
    ];
    for (word, name) in macros {
        for at in word_positions(line, word) {
            let end = at + word.len();
            if end < b.len() && b[end] == b'!' {
                hits.push((at, name));
            }
        }
    }
    hits.sort_by_key(|h| h.0);
    hits
}

/// FMA-contract violations on a (masked) line: `fmadd` anywhere,
/// `vfma`-prefixed intrinsics, or a `mul_add(` call.
fn fma_hits(line: &str) -> Vec<(usize, String)> {
    let b = line.as_bytes();
    let mut hits = Vec::new();
    let mut from = 0usize;
    while let Some(p) = line[from..].find("fmadd") {
        let at = from + p;
        hits.push((at, "fmadd".to_string()));
        from = at + 5;
    }
    from = 0;
    while let Some(p) = line[from..].find("vfma") {
        let at = from + p;
        if at == 0 || !is_ident_b(b[at - 1]) {
            // extend over the full intrinsic name for the message
            let mut end = at + 4;
            while end < b.len() && is_ident_b(b[end]) {
                end += 1;
            }
            hits.push((at, line[at..end].to_string()));
        }
        from = at + 4;
    }
    for at in word_positions(line, "mul_add") {
        let j = skip_ws(b, at + 7);
        if j < b.len() && b[j] == b'(' {
            hits.push((at, "mul_add(".to_string()));
        }
    }
    hits.sort_by_key(|h| h.0);
    hits
}

/// Matching `]` for the `[` at `open`, honoring nested `[]{}()`.
fn find_matching(masked: &[u8], open: usize) -> Option<usize> {
    let mut stack: Vec<u8> = Vec::new();
    let mut i = open;
    while i < masked.len() {
        match masked[i] {
            b'[' => stack.push(b']'),
            b'(' => stack.push(b')'),
            b'{' => stack.push(b'}'),
            c @ (b']' | b')' | b'}') => {
                if stack.last() == Some(&c) {
                    stack.pop();
                    if stack.is_empty() {
                        return Some(i);
                    }
                } else if stack.is_empty() {
                    return None;
                }
            }
            _ => {}
        }
        i += 1;
    }
    None
}

fn line_of(masked: &str, pos: usize) -> usize {
    masked.as_bytes()[..pos].iter().filter(|&&b| b == b'\n').count()
}

// ------------------------------------------------------ per-file state

struct FileSrc {
    rel: String,
    masked: String,
    mlines: Vec<String>,
    comments: BTreeMap<usize, String>,
    in_test: Vec<bool>,
    /// line -> (rules named, reason)
    pragmas: BTreeMap<usize, (Vec<String>, String)>,
}

/// Which lines sit inside `#[cfg(test)]` / `mod tests` brace regions.
fn test_lines(mlines: &[String]) -> Vec<bool> {
    let mut out = Vec::with_capacity(mlines.len());
    let mut depth = 0i64;
    let mut pending = false;
    let mut regions: Vec<i64> = Vec::new();
    for ln in mlines {
        let active_at_start = !regions.is_empty();
        let mut opened_here = false;
        if has_cfg_test(ln) || has_mod_tests(ln) {
            pending = true;
        }
        for ch in ln.chars() {
            match ch {
                '{' => {
                    depth += 1;
                    if pending {
                        regions.push(depth);
                        pending = false;
                        opened_here = true;
                    }
                }
                '}' => {
                    if regions.last() == Some(&depth) {
                        regions.pop();
                    }
                    depth -= 1;
                }
                ';' => pending = false,
                _ => {}
            }
        }
        out.push(active_at_start || opened_here);
    }
    out
}

/// Parse a `lint: allow(rule[, rule]): reason` pragma out of one line's
/// comment text. Returns `(rules, reason)`; the reason may be empty (a
/// `pragma` finding, but the named rules still suppress -- one finding,
/// not two).
fn parse_pragma(text: &str) -> Option<(Vec<String>, String)> {
    let at = text.find("lint:")?;
    let rest = &text[at + 5..];
    let b = rest.as_bytes();
    let mut i = skip_ws(b, 0);
    if !rest[i..].starts_with("allow") {
        return None;
    }
    i = skip_ws(b, i + 5);
    if i >= b.len() || b[i] != b'(' {
        return None;
    }
    let close = rest[i..].find(')')? + i;
    let rules: Vec<String> = rest[i + 1..close]
        .split(',')
        .map(|r| r.trim().to_string())
        .filter(|r| !r.is_empty())
        .collect();
    let mut j = skip_ws(b, close + 1);
    let mut reason = String::new();
    if j < b.len() && b[j] == b':' {
        j = skip_ws(b, j + 1);
        reason = rest[j..].trim().to_string();
    }
    Some((rules, reason))
}

impl FileSrc {
    fn new(rel: String, src: &str) -> FileSrc {
        let (masked, comments) = mask(src);
        let mlines: Vec<String> = masked.split('\n').map(|s| s.to_string()).collect();
        let in_test = test_lines(&mlines);
        let mut pragmas = BTreeMap::new();
        for (&line, text) in &comments {
            if let Some(p) = parse_pragma(text) {
                pragmas.insert(line, p);
            }
        }
        FileSrc {
            rel,
            masked,
            mlines,
            comments,
            in_test,
            pragmas,
        }
    }

    fn is_blank_code(&self, i: usize) -> bool {
        self.mlines[i].trim().is_empty()
    }

    fn is_attr_only(&self, i: usize) -> bool {
        self.mlines[i].trim().starts_with('#')
    }

    /// Lines whose comments may justify or suppress a finding at `line`:
    /// the line itself, then upward over comment-only lines (attribute
    /// lines skipped); any other code or a fully blank line stops the
    /// walk.
    fn walk_lines(&self, line: usize) -> Vec<usize> {
        let mut out = vec![line];
        let mut i = line;
        while i > 0 {
            i -= 1;
            if self.is_blank_code(i) && self.comments.contains_key(&i) {
                out.push(i);
            } else if self.is_attr_only(i) {
                continue;
            } else {
                break;
            }
        }
        out
    }

    fn has_safety(&self, line: usize) -> bool {
        for i in self.walk_lines(line) {
            if let Some(t) = self.comments.get(&i) {
                if t.contains("SAFETY:") || t.contains("# Safety") {
                    return true;
                }
            }
        }
        false
    }

    fn pragma_for(&self, rule: &str, line: usize) -> Option<usize> {
        for i in self.walk_lines(line) {
            if let Some((rules, _)) = self.pragmas.get(&i) {
                if rules.iter().any(|r| r == rule) {
                    return Some(i);
                }
            }
        }
        None
    }
}

// -------------------------------------------------------- rule driver

struct Sink<'a> {
    file: &'a FileSrc,
    report: &'a mut Report,
}

impl Sink<'_> {
    /// Record a finding at 0-based `line`, routing through the pragma
    /// check (a matching pragma turns it into a counted suppression).
    fn add(&mut self, rule: &str, line: usize, message: String) {
        if let Some(p) = self.file.pragma_for(rule, line) {
            let reason = self
                .file
                .pragmas
                .get(&p)
                .map(|(_, r)| r.clone())
                .unwrap_or_default();
            self.report.suppressed.push(Suppression {
                file: self.file.rel.clone(),
                line: line + 1,
                rule: rule.to_string(),
                reason,
            });
        } else {
            self.report.findings.push(Finding {
                file: self.file.rel.clone(),
                line: line + 1,
                rule: rule.to_string(),
                message,
            });
        }
    }

    /// Record a finding that no pragma can suppress (pragma hygiene).
    fn add_raw(&mut self, rule: &str, line: usize, message: String) {
        self.report.findings.push(Finding {
            file: self.file.rel.clone(),
            line: line + 1,
            rule: rule.to_string(),
            message,
        });
    }
}

fn lint_one(file: &FileSrc, serving: bool, fma_scope: bool, report: &mut Report) {
    let mut sink = Sink { file, report };
    // pragma hygiene: unknown rules and missing reasons are findings
    for (&line, (rules, reason)) in &file.pragmas {
        for r in rules {
            if !RULES.contains(&r.as_str()) {
                sink.add_raw(
                    "pragma",
                    line,
                    format!("allow pragma names unknown rule `{r}`"),
                );
            }
        }
        if reason.is_empty() {
            sink.add_raw(
                "pragma",
                line,
                "allow pragma without a `: <reason>`".to_string(),
            );
        }
    }
    // safety: every `unsafe` token, everywhere
    for i in 0..file.mlines.len() {
        if !word_positions(&file.mlines[i], "unsafe").is_empty() && !file.has_safety(i) {
            sink.add(
                "safety",
                i,
                "`unsafe` without an immediately preceding `// SAFETY:` comment".to_string(),
            );
        }
    }
    // fma: kernel reach set only
    if fma_scope {
        for i in 0..file.mlines.len() {
            for (_, tok) in fma_hits(&file.mlines[i]) {
                sink.add(
                    "fma",
                    i,
                    format!("FMA contract violation: `{tok}` (kernel reach set is no-FMA)"),
                );
            }
        }
    }
    if !serving {
        return;
    }
    // panic family, outside test regions
    for i in 0..file.mlines.len() {
        if file.in_test[i] {
            continue;
        }
        for (_, tok) in panic_hits(&file.mlines[i]) {
            sink.add("panic", i, format!("`{tok}` on the serving path"));
        }
    }
    // arithmetic indexing
    let mb = file.masked.as_bytes();
    for pos in 0..mb.len() {
        if mb[pos] != b'[' {
            continue;
        }
        // previous non-whitespace char must end a place expression
        let mut p = pos;
        let mut prev = 0u8;
        while p > 0 {
            p -= 1;
            if !(mb[p] as char).is_whitespace() {
                prev = mb[p];
                break;
            }
        }
        if !(is_ident_b(prev) || prev == b')' || prev == b']' || prev == b'?') {
            continue;
        }
        let line = line_of(&file.masked, pos);
        if file.in_test[line] {
            continue;
        }
        let end = match find_matching(mb, pos) {
            Some(e) => e,
            None => continue,
        };
        let idx = file.masked[pos + 1..end]
            .replace("->", "")
            .replace("=>", "");
        if idx.bytes().any(|b| matches!(b, b'+' | b'-' | b'*' | b'/' | b'%')) {
            let short: Vec<&str> = idx.split_whitespace().collect();
            sink.add(
                "index",
                line,
                format!(
                    "arithmetic index expression `{}` (prove bounds or use get())",
                    short.join(" ")
                ),
            );
        }
    }
    // discarded send results
    for at in word_positions(&file.masked, "let") {
        let j = skip_ws(mb, at + 3);
        if j == at + 3 || j >= mb.len() || mb[j] != b'_' {
            continue;
        }
        if j + 1 < mb.len() && is_ident_b(mb[j + 1]) {
            continue; // `let _name`, a real binding
        }
        let k = skip_ws(mb, j + 1);
        if k >= mb.len() || mb[k] != b'=' {
            continue;
        }
        let line = line_of(&file.masked, at);
        if file.in_test[line] {
            continue;
        }
        // statement text: to the `;` at nesting level 0
        let mut depth = 0i64;
        let mut i2 = k + 1;
        while i2 < mb.len() {
            match mb[i2] {
                b'(' | b'[' | b'{' => depth += 1,
                b')' | b']' | b'}' => depth -= 1,
                b';' if depth <= 0 => break,
                _ => {}
            }
            i2 += 1;
        }
        let stmt = &file.masked[k + 1..i2.min(mb.len())];
        if stmt_has_send(stmt) {
            sink.add(
                "send-discard",
                line,
                "channel send result discarded with `let _ =` (hides an abandoned caller)"
                    .to_string(),
            );
        }
    }
}

fn stmt_has_send(stmt: &str) -> bool {
    let b = stmt.as_bytes();
    for word in ["send", "try_send"] {
        for at in word_positions(stmt, word) {
            if at == 0 || b[at - 1] != b'.' {
                continue;
            }
            let j = skip_ws(b, at + word.len());
            if j < b.len() && b[j] == b'(' {
                return true;
            }
        }
    }
    false
}

// --------------------------------------------------------- fma reach

/// `(module dir for children, parent module dir)` of a source file.
fn module_dirs(root: &Path, file: &Path) -> (PathBuf, PathBuf) {
    let fdir = file.parent().unwrap_or(root).to_path_buf();
    let name = file
        .file_name()
        .and_then(|s| s.to_str())
        .unwrap_or("")
        .to_string();
    if name == "mod.rs" {
        let parent = fdir.parent().unwrap_or(root).to_path_buf();
        (fdir, parent)
    } else if file == root.join("lib.rs") || file == root.join("main.rs") {
        (fdir.clone(), fdir)
    } else {
        let stem = name.strip_suffix(".rs").unwrap_or(&name).to_string();
        (fdir.join(stem), fdir)
    }
}

fn resolve_use(dir: &Path, segs: &[String]) -> Option<PathBuf> {
    for k in (1..=segs.len()).rev() {
        let mut base = dir.to_path_buf();
        for s in &segs[..k] {
            base.push(s);
        }
        let rs = base.with_extension("rs");
        if rs.is_file() {
            return Some(rs);
        }
        let m = base.join("mod.rs");
        if m.is_file() {
            return Some(m);
        }
    }
    None
}

/// Files this file's `use` statements resolve to, within the tree.
fn uses_of(root: &Path, file: &Path, masked: &str) -> Vec<PathBuf> {
    let (mod_dir, parent) = module_dirs(root, file);
    let mut out = Vec::new();
    for at in word_positions(masked, "use") {
        let after = at + 3;
        let b = masked.as_bytes();
        if after >= b.len() || !(b[after] as char).is_whitespace() {
            continue;
        }
        let rest = &masked[after..];
        let stmt = match rest.find(';') {
            Some(e) => &rest[..e],
            None => continue,
        };
        let path_part = match stmt.find('{') {
            Some(p) => &stmt[..p],
            None => stmt,
        };
        let segs: Vec<String> = path_part
            .split("::")
            .map(|s| s.trim().to_string())
            .filter(|s| !s.is_empty())
            .collect();
        if segs.is_empty() {
            continue;
        }
        let resolved = match segs[0].as_str() {
            "crate" => resolve_use(root, &segs[1..]),
            "super" => {
                let mut d = parent.clone();
                let mut rest_segs = &segs[1..];
                while !rest_segs.is_empty() && rest_segs[0] == "super" {
                    d = d.parent().unwrap_or(root).to_path_buf();
                    rest_segs = &rest_segs[1..];
                }
                resolve_use(&d, rest_segs)
            }
            "self" => resolve_use(&mod_dir, &segs[1..]),
            "std" | "core" | "alloc" | "anyhow" | "xla" => None,
            _ => resolve_use(&mod_dir, &segs)
                .or_else(|| resolve_use(&parent, &segs))
                .or_else(|| resolve_use(root, &segs)),
        };
        if let Some(f) = resolved {
            out.push(f);
        }
    }
    out
}

// -------------------------------------------------------- tree driver

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> Result<()> {
    let mut entries: Vec<PathBuf> = std::fs::read_dir(dir)
        .with_context(|| format!("reading {}", dir.display()))?
        .filter_map(|e| e.ok().map(|e| e.path()))
        .collect();
    entries.sort();
    for p in entries {
        if p.is_dir() {
            collect_rs(&p, out)?;
        } else if p.extension().and_then(|e| e.to_str()) == Some("rs") {
            out.push(p);
        }
    }
    Ok(())
}

fn rel_label(root: &Path, file: &Path) -> String {
    file.strip_prefix(root)
        .unwrap_or(file)
        .to_string_lossy()
        .replace('\\', "/")
}

fn find_wire_version(masked: &str) -> Option<(String, usize)> {
    let b = masked.as_bytes();
    for at in word_positions(masked, "WIRE_VERSION") {
        let mut i = skip_ws(b, at + 12);
        if i >= b.len() || b[i] != b':' {
            continue;
        }
        i = skip_ws(b, i + 1);
        if !masked[i..].starts_with("u16") {
            continue;
        }
        i = skip_ws(b, i + 3);
        if i >= b.len() || b[i] != b'=' {
            continue;
        }
        i = skip_ws(b, i + 1);
        let start = i;
        while i < b.len() && b[i].is_ascii_digit() {
            i += 1;
        }
        if i > start {
            return Some((masked[start..i].to_string(), line_of(masked, at) + 1));
        }
    }
    None
}

fn find_doc_version(doc: &str) -> Option<String> {
    let b = doc.as_bytes();
    let mut from = 0usize;
    while let Some(p) = doc[from..].find("contract-lint:") {
        let at = from + p;
        let mut i = skip_ws(b, at + 14);
        if doc[i..].starts_with("wire-version") {
            i = skip_ws(b, i + 12);
            if i < b.len() && b[i] == b'=' {
                i = skip_ws(b, i + 1);
                let start = i;
                while i < b.len() && b[i].is_ascii_digit() {
                    i += 1;
                }
                if i > start {
                    return Some(doc[start..i].to_string());
                }
            }
        }
        from = at + 14;
    }
    None
}

/// Lint one source string under explicit scoping flags (the corpus tests
/// drive this directly; `lint_tree` derives the flags from the path).
pub fn lint_source(label: &str, src: &str, serving: bool, fma_scope: bool) -> Report {
    let file = FileSrc::new(label.to_string(), src);
    let mut report = Report::default();
    lint_one(&file, serving, fma_scope, &mut report);
    report.sort();
    report
}

/// Lint a source tree rooted at `root` (normally `rust/src`). `wire_doc`
/// is the wire-format ADR checked by the `wire-version` rule (skipped
/// entirely when the root has no `rfc/wire.rs`).
pub fn lint_tree(root: &Path, wire_doc: &Path) -> Result<Report> {
    let mut files = Vec::new();
    collect_rs(root, &mut files)?;
    let mut parsed: BTreeMap<PathBuf, FileSrc> = BTreeMap::new();
    for f in &files {
        let src = std::fs::read_to_string(f)
            .with_context(|| format!("reading {}", f.display()))?;
        parsed.insert(f.clone(), FileSrc::new(rel_label(root, f), &src));
    }
    // fma reach: BFS over `use` edges from rfc/kernel.rs
    let kernel = root.join("rfc").join("kernel.rs");
    let mut reach: BTreeSet<PathBuf> = BTreeSet::new();
    if parsed.contains_key(&kernel) {
        let mut frontier = vec![kernel.clone()];
        reach.insert(kernel);
        while let Some(f) = frontier.pop() {
            let masked = parsed.get(&f).map(|p| p.masked.clone()).unwrap_or_default();
            for dep in uses_of(root, &f, &masked) {
                if parsed.contains_key(&dep) && !reach.contains(&dep) {
                    reach.insert(dep.clone());
                    frontier.push(dep);
                }
            }
        }
    }
    let mut report = Report::default();
    for f in &files {
        let fs = &parsed[f];
        let serving = fs.rel.starts_with("coordinator/") || fs.rel == "rfc/wire.rs";
        lint_one(fs, serving, reach.contains(f), &mut report);
    }
    // wire-version agreement
    let wire = root.join("rfc").join("wire.rs");
    if let Some(wfs) = parsed.get(&wire) {
        let wv = find_wire_version(&wfs.masked);
        let sv = parsed
            .get(&root.join("sim").join("rfc.rs"))
            .and_then(|p| find_wire_version(&p.masked));
        let dv = std::fs::read_to_string(wire_doc)
            .ok()
            .and_then(|d| find_doc_version(&d));
        match wv {
            None => report.findings.push(Finding {
                file: wfs.rel.clone(),
                line: 1,
                rule: "wire-version".to_string(),
                message: "no `WIRE_VERSION: u16 = N` constant found".to_string(),
            }),
            Some((v, line)) => {
                let sim_ok = matches!(&sv, Some((s, _)) if *s == v);
                let doc_ok = matches!(&dv, Some(d) if *d == v);
                if !sim_ok {
                    let (got, at) = match &sv {
                        Some((s, sl)) => (format!("v{s}"), *sl),
                        None => ("no WIRE_VERSION const".to_string(), 1),
                    };
                    report.findings.push(Finding {
                        file: "sim/rfc.rs".to_string(),
                        line: at,
                        rule: "wire-version".to_string(),
                        message: format!(
                            "sim mirror declares {got}, rfc/wire.rs declares v{v} \
                             (bump all three together)"
                        ),
                    });
                } else if !doc_ok {
                    let got = match &dv {
                        Some(d) => format!("v{d}"),
                        None => "no `contract-lint: wire-version` marker".to_string(),
                    };
                    report.findings.push(Finding {
                        file: wfs.rel.clone(),
                        line,
                        rule: "wire-version".to_string(),
                        message: format!(
                            "{} declares {got}, rfc/wire.rs declares v{v} \
                             (bump all three together)",
                            wire_doc.display()
                        ),
                    });
                }
            }
        }
    }
    report.sort();
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn masking_strings_and_comments() {
        let (m, c) = mask("let s = \"unsafe { }\"; // SAFETY: nope\nfmadd();\n");
        assert!(!m.contains("unsafe"));
        assert!(m.contains("fmadd"));
        assert!(c.get(&0).map(|t| t.contains("SAFETY:")).unwrap_or(false));
        // newlines survive masking
        assert_eq!(m.matches('\n').count(), 2);
    }

    #[test]
    fn masking_raw_strings_and_chars() {
        let (m, _) = mask("let r = r#\"unwrap() \"# ; let c = '\\n'; let lt: &'a u8;");
        assert!(!m.contains("unwrap"));
        assert!(m.contains("&'a u8"));
    }

    #[test]
    fn nested_block_comments_mask_fully() {
        let (m, c) = mask("/* outer /* inner */ still comment */ code();");
        assert!(m.contains("code()"));
        assert!(!m.contains("inner"));
        assert!(c.get(&0).map(|t| t.contains("inner")).unwrap_or(false));
    }

    #[test]
    fn panic_tokens_found_and_bounded() {
        let hits = panic_hits("x.unwrap(); y.unwrap_or(0); z.expect(\"m\"); panic!(\"x\")");
        let names: Vec<&str> = hits.iter().map(|h| h.1).collect();
        assert_eq!(names, vec!["unwrap()", ".expect(", "panic!"]);
        assert!(panic_hits("debug_assert_eq!(a, b);")
            .iter()
            .any(|h| h.1 == "debug_assert!"));
        // expect as a free function (not a method) is not the Option API
        assert!(panic_hits("wire::expect_handshake(&mut r)?").is_empty());
    }

    #[test]
    fn fma_tokens() {
        assert!(!fma_hits("_mm256_fmadd_ps(a, b, c)").is_empty());
        assert!(!fma_hits("vfmaq_f32(a, b, c)").is_empty());
        assert!(!fma_hits("x.mul_add(y, z)").is_empty());
        assert!(fma_hits("let smul_addr = 3;").is_empty());
        assert!(fma_hits("vaddq_f32(ov, vmulq_f32(xs, wv))").is_empty());
    }

    #[test]
    fn test_region_tracking() {
        let src = "fn a() { x.unwrap(); }\n\
                   #[cfg(test)]\n\
                   mod tests {\n\
                   fn b() { y.unwrap(); }\n\
                   }\n\
                   fn c() { z.unwrap(); }\n";
        let r = lint_source("coordinator/x.rs", src, true, false);
        let lines: Vec<usize> = r.findings.iter().map(|f| f.line).collect();
        assert_eq!(lines, vec![1, 6]);
    }

    #[test]
    fn cfg_test_attribute_without_braces_cancelled_by_semicolon() {
        // `#[cfg(test)] mod tests;` (out-of-line) must not start a region
        let src = "#[cfg(test)]\nmod tests;\nfn a() { x.unwrap(); }\n";
        let r = lint_source("coordinator/x.rs", src, true, false);
        assert_eq!(r.findings.len(), 1);
        assert_eq!(r.findings[0].line, 3);
    }

    #[test]
    fn pragma_suppresses_and_is_counted() {
        let src = "fn a() {\n\
                   // lint: allow(panic): provably infallible here\n\
                   x.unwrap();\n\
                   }\n";
        let r = lint_source("coordinator/x.rs", src, true, false);
        assert!(r.findings.is_empty());
        assert_eq!(r.suppressed.len(), 1);
        assert_eq!(r.suppressed[0].rule, "panic");
        assert!(r.suppressed[0].reason.contains("infallible"));
    }

    #[test]
    fn pragma_without_reason_is_a_finding_but_still_suppresses() {
        let src = "fn a() {\n// lint: allow(panic)\nx.unwrap();\n}\n";
        let r = lint_source("coordinator/x.rs", src, true, false);
        assert_eq!(r.findings.len(), 1);
        assert_eq!(r.findings[0].rule, "pragma");
        assert_eq!(r.suppressed.len(), 1);
    }

    #[test]
    fn pragma_with_unknown_rule_is_a_finding() {
        let src = "// lint: allow(everything): because\nfn a() {}\n";
        let r = lint_source("coordinator/x.rs", src, true, false);
        assert_eq!(r.findings.len(), 1);
        assert_eq!(r.findings[0].rule, "pragma");
    }

    #[test]
    fn safety_walk_skips_attributes() {
        let src = "// SAFETY: bounds proven by the caller\n\
                   #[inline]\n\
                   unsafe fn f() {}\n";
        let r = lint_source("rfc/kernel.rs", src, false, false);
        assert!(r.findings.is_empty());
        // a code line between comment and unsafe breaks the adjacency
        let src2 = "// SAFETY: stale\nfn other() {}\nunsafe fn f() {}\n";
        let r2 = lint_source("rfc/kernel.rs", src2, false, false);
        assert_eq!(r2.findings.len(), 1);
        assert_eq!(r2.findings[0].rule, "safety");
    }

    #[test]
    fn doc_safety_section_counts() {
        let src = "/// Does things.\n///\n/// # Safety\n/// Caller checks AVX2.\n\
                   #[target_feature(enable = \"avx2\")]\n\
                   unsafe fn f() {}\n";
        let r = lint_source("rfc/kernel.rs", src, false, false);
        assert!(r.findings.is_empty());
    }

    #[test]
    fn index_rule_wants_arithmetic() {
        let src = "fn f(v: &[u8], i: usize, n: usize) -> u8 {\n\
                   let a = v[i];\n\
                   let b = v[i * n + 1];\n\
                   a + b\n}\n";
        let r = lint_source("coordinator/x.rs", src, true, false);
        assert_eq!(r.findings.len(), 1);
        assert_eq!(r.findings[0].rule, "index");
        assert_eq!(r.findings[0].line, 3);
    }

    #[test]
    fn index_rule_ignores_attrs_macros_and_match_arms() {
        let src = "#[cfg(feature = \"x\")]\n\
                   fn f(n: usize) -> Vec<u8> {\n\
                   let v = vec![0u8; n + 1];\n\
                   match n { _ => v }\n\
                   }\n";
        let r = lint_source("coordinator/x.rs", src, true, false);
        assert!(r.findings.is_empty());
    }

    #[test]
    fn send_discard_found_and_scoped() {
        let src = "fn f(tx: &S) {\n\
                   let _ = tx.send(1);\n\
                   let _ = sock.shutdown(Both);\n\
                   let _x = tx.send(2);\n\
                   }\n";
        let r = lint_source("coordinator/x.rs", src, true, false);
        assert_eq!(r.findings.len(), 1);
        assert_eq!(r.findings[0].rule, "send-discard");
        assert_eq!(r.findings[0].line, 2);
    }

    #[test]
    fn wire_version_parsers() {
        let (m, _) = mask("pub const WIRE_VERSION: u16 = 7;\n");
        assert_eq!(find_wire_version(&m), Some(("7".to_string(), 1)));
        assert_eq!(
            find_doc_version("x\n<!-- contract-lint: wire-version = 7 -->\n"),
            Some("7".to_string())
        );
        assert_eq!(find_doc_version("no marker here"), None);
    }

    #[test]
    fn non_serving_files_skip_serving_rules() {
        let src = "fn f() { x.unwrap(); let _ = tx.send(1); }\n";
        let r = lint_source("rfc/encoder.rs", src, false, false);
        assert!(r.findings.is_empty());
    }
}
