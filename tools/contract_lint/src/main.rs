//! CLI for the in-tree contract linter (library: [`contract_lint`]).
//!
//! ```text
//! contract_lint [--wire-doc <path>] [ROOT...]
//! ```
//!
//! Lints every `.rs` file under each ROOT (default: `rust/src`) and
//! cross-checks the wire-format ADR (default: `docs/wire-format.md`).
//!
//! Exit codes, in the `bench_ratchet` mold:
//! * `0` -- clean (suppressions are reported but do not fail the run);
//! * `1` -- at least one finding;
//! * `2` -- usage or I/O error.

use std::path::PathBuf;
use std::process::ExitCode;

use anyhow::{bail, ensure, Result};
use contract_lint::lint_tree;

struct Args {
    roots: Vec<PathBuf>,
    wire_doc: PathBuf,
}

fn parse_args(argv: &[String]) -> Result<Args> {
    let mut roots: Vec<PathBuf> = Vec::new();
    let mut wire_doc = PathBuf::from("docs/wire-format.md");
    let mut i = 0usize;
    while i < argv.len() {
        match argv[i].as_str() {
            "--wire-doc" => {
                i += 1;
                ensure!(i < argv.len(), "--wire-doc needs a path");
                wire_doc = PathBuf::from(&argv[i]);
            }
            "--help" | "-h" => {
                bail!("usage: contract_lint [--wire-doc <path>] [ROOT...]")
            }
            flag if flag.starts_with('-') => {
                bail!("unknown flag {flag}; usage: contract_lint [--wire-doc <path>] [ROOT...]")
            }
            root => roots.push(PathBuf::from(root)),
        }
        i += 1;
    }
    if roots.is_empty() {
        roots.push(PathBuf::from("rust/src"));
    }
    Ok(Args { roots, wire_doc })
}

fn run(args: &Args) -> Result<bool> {
    let mut findings = Vec::new();
    let mut suppressed = Vec::new();
    for root in &args.roots {
        ensure!(root.is_dir(), "{}: not a directory", root.display());
        let report = lint_tree(root, &args.wire_doc)?;
        findings.extend(report.findings);
        suppressed.extend(report.suppressed);
    }
    for f in &findings {
        println!("{}:{}: [{}] {}", f.file, f.line, f.rule, f.message);
    }
    if !suppressed.is_empty() {
        println!("-- {} suppression(s) via `lint: allow` pragmas:", suppressed.len());
        for s in &suppressed {
            println!("   {}:{}: [{}] {}", s.file, s.line, s.rule, s.reason);
        }
    }
    println!(
        "contract_lint: {} finding(s), {} suppression(s)",
        findings.len(),
        suppressed.len()
    );
    Ok(findings.is_empty())
}

fn main() -> ExitCode {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = match parse_args(&argv) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("contract_lint: {e:#}");
            return ExitCode::from(2);
        }
    };
    match run(&args) {
        Ok(true) => ExitCode::SUCCESS,
        Ok(false) => ExitCode::from(1),
        Err(e) => {
            eprintln!("contract_lint: {e:#}");
            ExitCode::from(2)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_args() {
        let a = parse_args(&[]).unwrap();
        assert_eq!(a.roots, vec![PathBuf::from("rust/src")]);
        assert_eq!(a.wire_doc, PathBuf::from("docs/wire-format.md"));
    }

    #[test]
    fn explicit_roots_and_doc() {
        let argv: Vec<String> = ["--wire-doc", "d.md", "a", "b"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        let a = parse_args(&argv).unwrap();
        assert_eq!(a.roots, vec![PathBuf::from("a"), PathBuf::from("b")]);
        assert_eq!(a.wire_doc, PathBuf::from("d.md"));
    }

    #[test]
    fn unknown_flag_is_an_error() {
        let argv = vec!["--frobnicate".to_string()];
        assert!(parse_args(&argv).is_err());
    }

    #[test]
    fn missing_root_is_an_error() {
        let args = Args {
            roots: vec![PathBuf::from("/nonexistent/lint/root")],
            wire_doc: PathBuf::from("docs/wire-format.md"),
        };
        assert!(run(&args).is_err());
    }
}
