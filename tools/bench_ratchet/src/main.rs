//! CI perf ratchet over `BENCH_rfc.json` (schema v2, emitted by
//! `rust/benches/rfc_throughput.rs` -- keep the two in sync).
//!
//! Compares a current benchmark emission against a baseline (the base
//! branch's artifact, or the checked-in `bench/BENCH_baseline.json` on
//! cold start) and fails on regression:
//!
//! * only numeric result fields ending in `_s` are ratcheted metrics
//!   (seconds, lower is better); everything else is context;
//! * result rows are matched by their `sparsity` key -- a row present
//!   on one side only is ignored (geometry changes are not regressions);
//! * a regression is `current > baseline * (1 + tolerance)`;
//! * comparisons only run between identical machine fingerprints
//!   (`machine.fingerprint`, `<arch>/<isa>/<cpus>cpu`): timings from a
//!   different runner class are incomparable, so a mismatch is a SKIP
//!   (exit 0), never a failure.
//!
//! Exit codes: 0 = ok or skipped, 1 = regression, 2 = malformed input.
//! The explicit override for an accepted slowdown is refreshing the
//! baseline file -- see `docs/bench-ratchet.md`.

use std::path::PathBuf;
use std::process::ExitCode;

use anyhow::{bail, ensure, Context, Result};
use rfc_hypgcn::util::json::Json;

/// Schema this tool understands; bump together with the bench emitter.
const SCHEMA_VERSION: usize = 2;

/// Default headroom before a slowdown counts as a regression.  Bench
/// timings on shared CI runners jitter; 25% is wide enough that noise
/// does not flake the job while a real (2x-style) regression still trips.
const DEFAULT_TOLERANCE: f64 = 0.25;

/// One metric that got slower than the baseline allows.
#[derive(Debug, Clone, PartialEq)]
struct Regression {
    row: String,
    metric: String,
    baseline_s: f64,
    current_s: f64,
    ratio: f64,
}

/// What the comparison concluded.
#[derive(Debug, PartialEq)]
enum Outcome {
    /// Fingerprints differ: timings are incomparable, nothing checked.
    Skipped { current: String, baseline: String },
    /// Fingerprints match: every shared `_s` metric was checked.
    Compared {
        metrics: usize,
        regressions: Vec<Regression>,
    },
}

fn fingerprint(doc: &Json) -> Result<String> {
    Ok(doc
        .get("machine")
        .context("bench document has no machine object")?
        .get("fingerprint")
        .context("machine object has no fingerprint")?
        .as_str()?
        .to_string())
}

/// Stable identity of a result row: its `sparsity` value.  Rows are
/// matched across documents by this key, not by position.
fn row_key(row: &Json) -> Result<String> {
    let s = row
        .get("sparsity")
        .context("result row has no sparsity key")?
        .as_f64()?;
    Ok(format!("sparsity={s}"))
}

fn check_schema(doc: &Json, which: &str) -> Result<()> {
    let v = doc
        .get("schema_version")
        .with_context(|| format!("{which}: missing schema_version"))?
        .as_usize()?;
    ensure!(
        v == SCHEMA_VERSION,
        "{which}: schema_version {v}, this tool understands {SCHEMA_VERSION}"
    );
    Ok(())
}

/// Compare two parsed bench documents.  Pure so the regression trip is
/// unit-testable (the acceptance check injects a slowdown through here).
fn compare(current: &Json, baseline: &Json, tolerance: f64) -> Result<Outcome> {
    check_schema(current, "current")?;
    check_schema(baseline, "baseline")?;
    ensure!(
        tolerance >= 0.0,
        "tolerance must be non-negative, got {tolerance}"
    );
    let cur_fp = fingerprint(current)?;
    let base_fp = fingerprint(baseline)?;
    if cur_fp != base_fp {
        return Ok(Outcome::Skipped {
            current: cur_fp,
            baseline: base_fp,
        });
    }
    let cur_rows = current.get("results")?.as_arr()?;
    let base_rows = baseline.get("results")?.as_arr()?;
    let mut metrics = 0usize;
    let mut regressions = Vec::new();
    for cur_row in cur_rows {
        let key = row_key(cur_row)?;
        let Some(base_row) = base_rows
            .iter()
            .find(|r| row_key(r).ok().as_deref() == Some(key.as_str()))
        else {
            continue; // new row: nothing to ratchet against
        };
        for (name, cur_v) in cur_row.as_obj()? {
            if !name.ends_with("_s") {
                continue; // not a timing metric
            }
            let Some(base_v) = base_row.opt(name) else {
                continue; // metric added since the baseline
            };
            let cur_s = cur_v
                .as_f64()
                .with_context(|| format!("{key}: {name} not numeric"))?;
            let base_s = base_v
                .as_f64()
                .with_context(|| format!("baseline {key}: {name} not numeric"))?;
            ensure!(
                cur_s > 0.0 && base_s > 0.0,
                "{key}: {name} must be positive seconds \
                 (current {cur_s}, baseline {base_s})"
            );
            metrics += 1;
            if cur_s > base_s * (1.0 + tolerance) {
                regressions.push(Regression {
                    row: key.clone(),
                    metric: name.clone(),
                    baseline_s: base_s,
                    current_s: cur_s,
                    ratio: cur_s / base_s,
                });
            }
        }
    }
    ensure!(
        metrics > 0,
        "no comparable `_s` metrics between current and baseline \
         (matched rows: {} of {})",
        cur_rows
            .iter()
            .filter(|r| {
                row_key(r).ok().is_some_and(|k| {
                    base_rows
                        .iter()
                        .any(|b| row_key(b).ok().as_deref() == Some(k.as_str()))
                })
            })
            .count(),
        cur_rows.len()
    );
    Ok(Outcome::Compared {
        metrics,
        regressions,
    })
}

struct Args {
    current: PathBuf,
    baseline: PathBuf,
    tolerance: f64,
}

fn parse_args() -> Result<Args> {
    let mut current = None;
    let mut baseline = None;
    let mut tolerance = DEFAULT_TOLERANCE;
    let mut it = std::env::args().skip(1);
    while let Some(arg) = it.next() {
        match arg.as_str() {
            "--current" => {
                current = Some(PathBuf::from(
                    it.next().context("--current needs a path")?,
                ));
            }
            "--baseline" => {
                baseline = Some(PathBuf::from(
                    it.next().context("--baseline needs a path")?,
                ));
            }
            "--tolerance" => {
                tolerance = it
                    .next()
                    .context("--tolerance needs a value")?
                    .parse()
                    .context("--tolerance must be a number")?;
            }
            other => bail!(
                "unknown argument {other:?} \
                 (usage: bench_ratchet --current <json> --baseline <json> \
                 [--tolerance <frac>])"
            ),
        }
    }
    Ok(Args {
        current: current.context("--current is required")?,
        baseline: baseline.context("--baseline is required")?,
        tolerance,
    })
}

fn run() -> Result<bool> {
    let args = parse_args()?;
    let current = Json::from_file(&args.current)
        .with_context(|| format!("parsing {}", args.current.display()))?;
    let baseline = Json::from_file(&args.baseline)
        .with_context(|| format!("parsing {}", args.baseline.display()))?;
    match compare(&current, &baseline, args.tolerance)? {
        Outcome::Skipped {
            current: c,
            baseline: b,
        } => {
            println!(
                "bench-ratchet: SKIP -- fingerprint mismatch \
                 (current {c:?} vs baseline {b:?}); timings from \
                 different runner classes are not comparable"
            );
            Ok(true)
        }
        Outcome::Compared {
            metrics,
            regressions,
        } => {
            if regressions.is_empty() {
                println!(
                    "bench-ratchet: OK -- {metrics} metrics within \
                     {:.0}% of baseline",
                    args.tolerance * 100.0
                );
                return Ok(true);
            }
            eprintln!(
                "bench-ratchet: FAIL -- {} of {metrics} metrics regressed \
                 beyond the {:.0}% tolerance:",
                regressions.len(),
                args.tolerance * 100.0
            );
            for r in &regressions {
                eprintln!(
                    "  {} {}: {:.6}s -> {:.6}s ({:.2}x)",
                    r.row, r.metric, r.baseline_s, r.current_s, r.ratio
                );
            }
            eprintln!(
                "to accept an intended slowdown, refresh the checked-in \
                 baseline (see docs/bench-ratchet.md)"
            );
            Ok(false)
        }
    }
}

fn main() -> ExitCode {
    match run() {
        Ok(true) => ExitCode::SUCCESS,
        Ok(false) => ExitCode::from(1),
        Err(e) => {
            eprintln!("bench-ratchet: error: {e:#}");
            ExitCode::from(2)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A minimal valid v2 document; `scale` multiplies every `_s` metric
    /// so tests can inject a uniform slowdown.
    fn doc(fingerprint: &str, scale: f64) -> Json {
        Json::parse(&format!(
            r#"{{
              "schema_version": 2,
              "bench": "rfc_throughput",
              "section": "kernel",
              "git_sha": "deadbeef",
              "machine": {{
                "arch": "x86_64", "cpus": 8, "isa": "avx2",
                "cpu_features": ["avx2"],
                "fingerprint": "{fingerprint}"
              }},
              "m": 512, "k": 256, "n": 64,
              "results": [
                {{"sparsity": 0.5, "dense_s": {d1}, "spmm_serial_s": {s1},
                  "spmm_scalar_s": {c1}, "skip_fraction": 0.5}},
                {{"sparsity": 0.9, "dense_s": {d2}, "spmm_serial_s": {s2},
                  "spmm_scalar_s": {c2}, "skip_fraction": 0.9}}
              ]
            }}"#,
            d1 = 0.010 * scale,
            s1 = 0.004 * scale,
            c1 = 0.008 * scale,
            d2 = 0.010 * scale,
            s2 = 0.002 * scale,
            c2 = 0.006 * scale,
        ))
        .unwrap()
    }

    #[test]
    fn identical_runs_pass() {
        let base = doc("x86_64/avx2/8cpu", 1.0);
        let cur = doc("x86_64/avx2/8cpu", 1.0);
        match compare(&cur, &base, 0.25).unwrap() {
            Outcome::Compared {
                metrics,
                regressions,
            } => {
                assert_eq!(metrics, 6, "3 `_s` metrics x 2 rows");
                assert!(regressions.is_empty());
            }
            o => panic!("expected Compared, got {o:?}"),
        }
    }

    #[test]
    fn slowdown_within_tolerance_passes() {
        let base = doc("x86_64/avx2/8cpu", 1.0);
        let cur = doc("x86_64/avx2/8cpu", 1.2); // +20% < 25% tolerance
        match compare(&cur, &base, 0.25).unwrap() {
            Outcome::Compared { regressions, .. } => {
                assert!(regressions.is_empty());
            }
            o => panic!("expected Compared, got {o:?}"),
        }
    }

    #[test]
    fn injected_regression_fails() {
        // the acceptance check: a 2x slowdown must trip the ratchet
        let base = doc("x86_64/avx2/8cpu", 1.0);
        let cur = doc("x86_64/avx2/8cpu", 2.0);
        match compare(&cur, &base, 0.25).unwrap() {
            Outcome::Compared {
                metrics,
                regressions,
            } => {
                assert_eq!(
                    regressions.len(),
                    metrics,
                    "a uniform 2x slowdown regresses every metric"
                );
                let r = &regressions[0];
                assert!((r.ratio - 2.0).abs() < 1e-9);
                assert!(r.metric.ends_with("_s"));
            }
            o => panic!("expected Compared, got {o:?}"),
        }
    }

    #[test]
    fn speedups_never_fail() {
        let base = doc("x86_64/avx2/8cpu", 1.0);
        let cur = doc("x86_64/avx2/8cpu", 0.5);
        match compare(&cur, &base, 0.25).unwrap() {
            Outcome::Compared { regressions, .. } => {
                assert!(regressions.is_empty());
            }
            o => panic!("expected Compared, got {o:?}"),
        }
    }

    #[test]
    fn fingerprint_mismatch_skips() {
        // cross-runner comparison (or the placeholder cold-start
        // baseline) must skip, not fail
        let base = doc("baseline-placeholder", 1.0);
        let cur = doc("x86_64/avx2/8cpu", 50.0); // wildly slower -- irrelevant
        match compare(&cur, &base, 0.25).unwrap() {
            Outcome::Skipped { current, baseline } => {
                assert_eq!(current, "x86_64/avx2/8cpu");
                assert_eq!(baseline, "baseline-placeholder");
            }
            o => panic!("expected Skipped, got {o:?}"),
        }
    }

    #[test]
    fn non_timing_fields_are_ignored() {
        // skip_fraction differs hugely but is not a `_s` metric
        let base = doc("f", 1.0);
        let mut cur = doc("f", 1.0);
        if let Json::Obj(m) = &mut cur {
            if let Some(Json::Arr(rows)) = m.get_mut("results") {
                if let Json::Obj(row) = &mut rows[0] {
                    row.insert("skip_fraction".into(), Json::Num(99.0));
                }
            }
        }
        match compare(&cur, &base, 0.25).unwrap() {
            Outcome::Compared { regressions, .. } => {
                assert!(regressions.is_empty());
            }
            o => panic!("expected Compared, got {o:?}"),
        }
    }

    #[test]
    fn rows_match_by_sparsity_not_position() {
        let base = doc("f", 1.0);
        let mut cur = doc("f", 1.0);
        if let Json::Obj(m) = &mut cur {
            if let Some(Json::Arr(rows)) = m.get_mut("results") {
                rows.reverse();
            }
        }
        match compare(&cur, &base, 0.25).unwrap() {
            Outcome::Compared {
                metrics,
                regressions,
            } => {
                assert_eq!(metrics, 6);
                assert!(regressions.is_empty());
            }
            o => panic!("expected Compared, got {o:?}"),
        }
    }

    #[test]
    fn unmatched_rows_are_not_regressions() {
        // current measures a sparsity the baseline never saw
        let base = doc("f", 1.0);
        let mut cur = doc("f", 1.0);
        if let Json::Obj(m) = &mut cur {
            if let Some(Json::Arr(rows)) = m.get_mut("results") {
                if let Json::Obj(row) = &mut rows[1] {
                    row.insert("sparsity".into(), Json::Num(0.7));
                    row.insert("spmm_serial_s".into(), Json::Num(100.0));
                }
            }
        }
        match compare(&cur, &base, 0.25).unwrap() {
            Outcome::Compared {
                metrics,
                regressions,
            } => {
                assert_eq!(metrics, 3, "only the matched row is ratcheted");
                assert!(regressions.is_empty());
            }
            o => panic!("expected Compared, got {o:?}"),
        }
    }

    #[test]
    fn malformed_documents_error() {
        let good = doc("f", 1.0);
        // wrong schema version
        let mut v1 = good.clone();
        if let Json::Obj(m) = &mut v1 {
            m.insert("schema_version".into(), Json::Num(1.0));
        }
        assert!(compare(&v1, &good, 0.25).is_err());
        // no machine fingerprint
        let mut no_fp = good.clone();
        if let Json::Obj(m) = &mut no_fp {
            m.insert("machine".into(), Json::Obj(Default::default()));
        }
        assert!(compare(&no_fp, &good, 0.25).is_err());
        // no overlapping metrics at all
        let mut empty = good.clone();
        if let Json::Obj(m) = &mut empty {
            m.insert("results".into(), Json::Arr(Vec::new()));
        }
        assert!(compare(&empty, &good, 0.25).is_err());
        // nonsensical tolerance
        assert!(compare(&good, &good, -0.5).is_err());
    }
}
